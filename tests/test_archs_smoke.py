"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates its REDUCED variant (<=2 layers,
d_model<=512, <=4 experts — same family code paths) and runs one forward /
train step on CPU asserting output shapes and the absence of NaNs; decoder
archs additionally run one serve step against a fresh cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.data import train_inputs
from repro.models import model as M
from repro.models.nn import split_params

B, S = 2, 64


def _build(arch):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    values, _ = split_params(params)
    batch = train_inputs(jax.random.PRNGKey(1), cfg, B, S)
    return cfg, values, batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg, values, batch = _build(arch)
    loss, metrics = jax.jit(
        lambda v, b: M.train_loss(v, cfg, b))(values, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    # one SGD step decreases nothing catastrophically (finite grads)
    grads = jax.grad(lambda v: M.train_loss(v, cfg, batch)[0])(values)
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shape(arch):
    cfg, values, batch = _build(arch)
    x, stats = jax.jit(lambda v, b: M.forward(v, cfg, b))(values, batch)
    assert x.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(x, np.float32)).all(), arch


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).has_decode])
def test_decode_step_smoke(arch):
    cfg, values, _ = _build(arch)
    cache_p = M.init_cache(cfg, B, 32)
    cache, _ = split_params(cache_p)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    logits, new_cache = jax.jit(
        lambda v, c, t, p: M.decode_step(v, cfg, c, t, p))(
        values, cache, tok, pos)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch
    assert jax.tree_util.tree_structure(new_cache) == \
        jax.tree_util.tree_structure(cache)


def test_encoder_skips_decode():
    cfg = reduced(get_config("hubert-xlarge"))
    assert not cfg.has_decode
    with pytest.raises(ValueError):
        M.init_cache(cfg, B, 32)


def test_exact_assigned_configs():
    """The FULL configs match the assignment table exactly."""
    t = {a: get_config(a) for a in ARCH_IDS}
    a = t["deepseek-v2-236b"]
    assert (a.num_layers, a.d_model, a.num_heads, a.vocab_size) == \
        (60, 5120, 128, 102400)
    assert (a.num_experts, a.top_k, a.num_shared_experts,
            a.moe_d_ff, a.kv_lora_rank) == (160, 6, 2, 1536, 512)
    z = t["zamba2-2.7b"]
    assert (z.num_layers, z.d_model, z.ssm_state, z.d_ff) == \
        (54, 2560, 64, 10240)
    m = t["minicpm3-4b"]
    assert (m.num_layers, m.d_model, m.num_heads, m.d_ff, m.vocab_size) == \
        (62, 2560, 40, 6400, 73448)
    c = t["codeqwen1.5-7b"]
    assert (c.num_layers, c.d_model, c.d_ff, c.vocab_size) == \
        (32, 4096, 13440, 92416)
    h = t["hubert-xlarge"]
    assert (h.num_layers, h.d_model, h.num_heads, h.d_ff, h.vocab_size) == \
        (48, 1280, 16, 5120, 504)
    assert h.is_encoder
    r = t["command-r-plus-104b"]
    assert (r.num_layers, r.d_model, r.num_heads, r.num_kv_heads, r.d_ff,
            r.vocab_size) == (64, 12288, 96, 8, 33792, 256000)
    x = t["xlstm-125m"]
    assert (x.num_layers, x.d_model, x.num_heads, x.vocab_size, x.d_ff) == \
        (12, 768, 4, 50304, 0)
    q = t["qwen2-vl-72b"]
    assert (q.num_layers, q.d_model, q.num_heads, q.num_kv_heads, q.d_ff,
            q.vocab_size) == (80, 8192, 64, 8, 29568, 152064)
    assert q.mrope
    qm = t["qwen3-moe-30b-a3b"]
    assert (qm.num_layers, qm.d_model, qm.num_heads, qm.num_kv_heads,
            qm.vocab_size) == (48, 2048, 32, 4, 151936)
    assert (qm.num_experts, qm.top_k, qm.moe_d_ff) == (128, 8, 768)
    q6 = t["qwen3-0.6b"]
    assert (q6.num_layers, q6.d_model, q6.num_heads, q6.num_kv_heads,
            q6.d_ff, q6.vocab_size) == (28, 1024, 16, 8, 3072, 151936)
    assert q6.qk_norm
