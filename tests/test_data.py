"""Synthetic data pipeline: determinism, structure, modality stubs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.data import SyntheticConfig, synthetic_batch, train_inputs
from repro.data.synthetic import make_batch_iterator


def test_deterministic():
    cfg = SyntheticConfig(vocab_size=100, seq_len=32, global_batch=4)
    key = jax.random.PRNGKey(0)
    b1 = synthetic_batch(key, cfg)
    b2 = synthetic_batch(key, cfg)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_labels_are_shifted_tokens():
    cfg = SyntheticConfig(vocab_size=50, seq_len=16, global_batch=2)
    b = synthetic_batch(jax.random.PRNGKey(1), cfg)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_tokens_in_range():
    cfg = SyntheticConfig(vocab_size=37, seq_len=64, global_batch=3)
    b = synthetic_batch(jax.random.PRNGKey(2), cfg)
    t = np.asarray(b["tokens"])
    assert t.min() >= 0 and t.max() < 37


def test_learnable_structure():
    """The deterministic grammar makes bigrams predictable more often than
    chance — the signal the example training runs learn."""
    cfg = SyntheticConfig(vocab_size=64, seq_len=512, global_batch=8,
                          copy_prob=0.5)
    b = synthetic_batch(jax.random.PRNGKey(3), cfg)
    t = np.asarray(b["tokens"])
    follow = (7 * t[:, :-1] + 13) % 64
    hit = (t[:, 1:] == follow).mean()
    assert hit > 0.3


def test_modality_stubs():
    audio = reduced(get_config("hubert-xlarge"))
    b = train_inputs(jax.random.PRNGKey(0), audio, 2, 16)
    assert b["features"].shape == (2, 16, 512)
    assert "tokens" not in b
    vlm = reduced(get_config("qwen2-vl-72b"))
    b = train_inputs(jax.random.PRNGKey(0), vlm, 2, 16)
    assert b["vision_embeds"].shape[0] == 2
    assert b["mrope_positions"].shape == (3, 2, 16)


def test_iterator_advances():
    cfg = reduced(get_config("qwen3-0.6b"))
    it = make_batch_iterator(cfg, 2, 8, seed=1)
    a = next(it)["tokens"]
    b = next(it)["tokens"]
    assert not np.array_equal(np.asarray(a), np.asarray(b))
