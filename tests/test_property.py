"""Hypothesis property-based tests on the system's invariants.

``hypothesis`` is an optional test dependency (declared under the
``test`` extra in pyproject.toml); the module skips cleanly without it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import theory
from repro.core.cgc import cgc_filter, cgc_scales, cgc_threshold
from repro.core.echo import echo_decision, project_onto_span

SETTINGS = dict(max_examples=40, deadline=None)


def _matrix(n, d, seed, spread):
    key = jax.random.PRNGKey(seed)
    G = jax.random.normal(key, (n, d))
    return G * (1 + spread * jnp.arange(n)[:, None])


@settings(**SETTINGS)
@given(n=st.integers(3, 24), d=st.integers(2, 64), seed=st.integers(0, 99),
       spread=st.floats(0.0, 5.0))
def test_cgc_scales_bounded(n, d, seed, spread):
    G = _matrix(n, d, seed, spread)
    f = n // 3
    s = np.asarray(cgc_scales(jnp.linalg.norm(G, axis=1), f))
    assert np.all(s <= 1.0 + 1e-6)
    assert np.all(s > 0)
    # exactly at most f gradients are scaled down
    assert int(np.sum(s < 1.0 - 1e-6)) <= f


@settings(**SETTINGS)
@given(n=st.integers(3, 16), d=st.integers(3, 32), seed=st.integers(0, 99))
def test_cgc_filtered_norms_capped(n, d, seed):
    G = _matrix(n, d, seed, 2.0)
    f = max(1, n // 4)
    out = cgc_filter(G, f)
    thr = float(cgc_threshold(jnp.linalg.norm(G, axis=1), f))
    norms = np.linalg.norm(np.asarray(out), axis=1)
    assert np.all(norms <= thr * (1 + 1e-4))


@settings(**SETTINGS)
@given(n=st.integers(2, 12), d=st.integers(4, 48), k=st.integers(1, 8),
       seed=st.integers(0, 99))
def test_projection_never_longer_than_g(n, d, k, seed):
    """||proj g|| <= ||g|| — projections are contractions."""
    k = min(k, n)
    key = jax.random.PRNGKey(seed)
    R = jax.random.normal(key, (n, d))
    mask = jnp.arange(n) < k
    g = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    _, echo = project_onto_span(R, mask, g)
    # exact projections contract; the ridge-regularised fp32 solve can
    # overshoot by ~1e-4 relative when span(R) is nearly full-rank (k ~ d),
    # so the invariant is asserted with a 1e-3 numerical allowance.
    assert float(jnp.linalg.norm(echo)) <= float(
        jnp.linalg.norm(g)) * (1 + 1e-3)


@settings(**SETTINGS)
@given(n=st.integers(2, 12), d=st.integers(4, 48), seed=st.integers(0, 99),
       r=st.floats(0.01, 2.0))
def test_echo_decision_residual_consistent(n, d, seed, r):
    key = jax.random.PRNGKey(seed)
    R = jax.random.normal(key, (n, d))
    mask = jnp.arange(n) < max(1, n // 2)
    g = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    dec = echo_decision(R, mask, g, r)
    res_ok = float(dec.residual) <= r * float(jnp.linalg.norm(g)) + 1e-6
    assert bool(dec.send_echo) == res_ok or not res_ok
    if bool(dec.send_echo):
        # Eq. 7 holds
        assert res_ok


@settings(**SETTINGS)
@given(n=st.integers(10, 200), x=st.floats(0.01, 0.12),
       sigma=st.floats(0.0, 0.09), mu_over_L=st.floats(0.6, 1.0))
def test_rho_valid_whenever_resilience_holds(n, x, sigma, mu_over_L):
    f = max(int(x * n), 0)
    L, mu = 1.0, mu_over_L
    if not theory.resilience_condition(n, f, L, mu):
        return
    r, eta, b, g, rho = theory.pick_r_eta(n, f, L, mu, sigma)
    assert r > 0 and eta > 0
    assert 0.0 <= rho < 1.0


@settings(**SETTINGS)
@given(sigma=st.floats(0.01, 0.12), x=st.floats(0.01, 0.1),
       n=st.integers(20, 400))
def test_comm_ratio_nonnegative_and_blows_up_at_xmax(sigma, x, n):
    C = theory.comm_ratio_C(sigma, x, 1.0, n)
    assert C >= 0.0
    xm = theory.x_max(sigma, 1.0, n)
    if x < 0.9 * xm:
        assert np.isfinite(C)


@settings(**SETTINGS)
@given(n=st.integers(4, 32), d=st.integers(64, 512),
       seed=st.integers(0, 20))
def test_kernel_cgc_matches_ref_property(n, d, seed):
    from repro.kernels import ops, ref
    G = _matrix(n, d, seed, 1.0)
    f = max(1, n // 4)
    np.testing.assert_allclose(np.asarray(ops.cgc_clip(G, f)),
                               np.asarray(ref.cgc_clip_ref(G, f)),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Wire codecs (repro.comm, DESIGN.md §9)
# ---------------------------------------------------------------------------

from repro.comm import (Bf16Codec, Fp32Codec, Int8Codec,  # noqa: E402
                        TopKCodec, payload_bits)

_CODEC_BUILDERS = [Fp32Codec, Bf16Codec, Int8Codec,
                   lambda: TopKCodec(k=8)]


@settings(**SETTINGS)
@given(which=st.integers(0, len(_CODEC_BUILDERS) - 1),
       m=st.integers(1, 256), seed=st.integers(0, 99),
       scale=st.floats(1e-6, 1e6))
def test_codec_roundtrip_and_bit_size_property(which, m, seed, scale):
    """Every codec: encode -> decode round-trips shape/dtype with its
    documented error bound, and the advertised vector_bits equals the
    bits actually in the encoded payload."""
    codec = _CODEC_BUILDERS[which]()
    v = scale * jax.random.normal(jax.random.PRNGKey(seed), (m,))
    payload = codec.encode(v)
    assert payload_bits(payload) == int(codec.vector_bits(m))
    rt = codec.decode(payload, m)
    assert rt.shape == v.shape and rt.dtype == jnp.float32
    err = np.abs(np.asarray(rt) - np.asarray(v))
    vmax = float(np.max(np.abs(np.asarray(v)))) + 1e-30
    if codec.lossless:
        assert np.array_equal(np.asarray(rt), np.asarray(v))
    elif codec.name == "bf16":
        assert np.all(err <= np.abs(np.asarray(v)) / 128 + 1e-7 * vmax)
    elif codec.name == "int8":
        assert np.all(err <= vmax / 127 * 0.5 + 1e-6 * vmax)
    else:                                      # topk: kept entries exact
        kept = np.asarray(rt) != 0.0
        np.testing.assert_array_equal(np.asarray(rt)[kept],
                                      np.asarray(v)[kept])
        assert kept.sum() <= codec.k


# ---------------------------------------------------------------------------
# RunConfig JSON round-trip (repro.run, DESIGN.md §8)
# ---------------------------------------------------------------------------

from repro.run import (RunConfig, CommSpec, DataSpec, MeshSpec,  # noqa: E402
                       ModelSpec, SamplingSpec, ScenarioSpec, ServeSpec,
                       TrainSpec, apply_overrides, available, config_hash)

_NAMES = available()
_FINITE = st.floats(allow_nan=False, allow_infinity=False, width=64)


@settings(**SETTINGS)
@given(agg=st.sampled_from(_NAMES["collective_aggregators"]),
       attack=st.sampled_from(_NAMES["attacks"]),
       strategy=st.sampled_from(_NAMES["train_strategies"]),
       codec=st.sampled_from(_NAMES["codecs"]),
       channel=st.sampled_from(_NAMES["channels"]),
       drop=st.floats(0.0, 0.999),
       f=st.integers(0, 50), steps=st.integers(0, 10 ** 6),
       lr=_FINITE, echo_r=_FINITE, noise=_FINITE,
       temp=_FINITE, top_k=st.integers(0, 10 ** 4),
       smoke=st.booleans(), devices=st.integers(0, 512),
       name=st.text(max_size=40),
       drop_train=st.booleans(), drop_serve=st.booleans())
def test_runconfig_json_roundtrip_property(agg, attack, strategy, codec,
                                           channel, drop, f, steps,
                                           lr, echo_r, noise, temp, top_k,
                                           smoke, devices, name,
                                           drop_train, drop_serve):
    """Lossless serialization over every registered scenario combination
    and arbitrary finite numerics (incl. sub-normals, huge exponents and
    unicode names): from_json(to_json(cfg)) == cfg, and the config hash
    is a pure function of content."""
    cfg = RunConfig(
        name=name,
        model=ModelSpec(arch="qwen3-0.6b", smoke=smoke),
        mesh=MeshSpec(devices=devices),
        scenario=ScenarioSpec(aggregator=agg, attack=attack, f=f,
                              echo_r=echo_r,
                              data=DataSpec(noise=noise),
                              comm=CommSpec(channel=channel, codec=codec,
                                            drop_prob=drop)),
        train=None if drop_train else TrainSpec(strategy=strategy,
                                                steps=steps, lr=lr),
        serve=None if drop_serve else ServeSpec(
            sampling=SamplingSpec(temperature=temp, top_k=top_k)))
    back = RunConfig.from_json(cfg.to_json())
    assert back == cfg
    assert config_hash(back) == config_hash(cfg)


@settings(**SETTINGS)
@given(steps=st.integers(0, 10 ** 9), lr=_FINITE)
def test_runconfig_override_matches_construction(steps, lr):
    """--set edits land exactly where direct construction would."""
    base = RunConfig(train=TrainSpec())
    out = apply_overrides(base, [f"train.steps={steps}",
                                 f"train.lr={lr!r}"])
    want = RunConfig(train=TrainSpec(steps=steps, lr=float(repr(lr))))
    assert out == want
