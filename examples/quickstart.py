"""Quickstart: Echo-CGC on a strongly-convex problem in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Runs the faithful single-hop radio-network simulation (Algorithm 1) with
f Byzantine workers sign-flipping their gradients, prints convergence and
the measured communication saving vs the point-to-point baseline.
"""
import jax
import jax.numpy as jnp

from repro.core import byzantine, costfns, theory
from repro.core.protocol import run_training
from repro.core.types import ProtocolConfig, raw_bits


def main():
    key = jax.random.PRNGKey(0)
    n, f, d, sigma = 20, 2, 100, 0.05
    rounds = 60

    # A quadratic cost with known (L, mu) and relative gradient noise sigma.
    cost = costfns.quadratic(key, d=d, mu=1.0, L=1.0, sigma=sigma)

    # Admissible (r, eta) from the paper's Lemma 4 / Theorem 5.
    r, eta, beta, gamma, rho = theory.pick_r_eta(n, f, cost.L, cost.mu,
                                                 sigma)
    print(f"n={n} f={f} d={d} sigma={sigma}")
    print(f"deviation ratio r={r:.4f}  step size eta={eta:.5f}  "
          f"proven rate rho={rho:.4f}")

    cfg = ProtocolConfig(n=n, f=f, r=r, eta=eta)
    byz_mask = jnp.zeros(n, bool).at[:f].set(True)
    trace = run_training(cfg, cost, byzantine.ATTACKS["sign_flip"],
                         byz_mask, key, jnp.ones(d) * 2.0, rounds=rounds)

    d2 = trace["dist2"]
    print(f"\n||w - w*||^2 : {float(d2[0]):.4f} -> {float(d2[-1]):.2e} "
          f"in {rounds} rounds (under {f} sign-flipping workers)")

    bits = float(jnp.sum(trace["bits"]))
    p2p = rounds * n * raw_bits(d)
    print(f"bits sent    : {bits:.3g} vs point-to-point {p2p:.3g} "
          f"-> saving {100 * (1 - bits / p2p):.1f}%")
    print(f"echo rate    : {float(jnp.mean(trace['n_echo'])) / (n - 1):.2%} "
          f"of eligible workers per round")


if __name__ == "__main__":
    main()
