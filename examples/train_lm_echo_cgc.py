"""End-to-end driver: train a language model with CGC-filtered aggregation.

    PYTHONPATH=src python examples/train_lm_echo_cgc.py \
        --preset demo --steps 300            # ~20M params, CPU-friendly
    PYTHONPATH=src python examples/train_lm_echo_cgc.py \
        --preset 100m --steps 200            # ~100M params (slow on CPU)

The trainer is the production path from repro.launch.train: data-parallel
workers (simulated in-process on CPU; mesh shards on real hardware), CGC
aggregation over per-worker gradients, AdamW, checkpointing, deterministic
synthetic data. ``--byz K`` makes K workers Byzantine to demonstrate the
filter on a real model. With a single host device the "workers" collapse to
one — pass --devices 8 to fork 8 CPU devices for true multi-worker DP.
"""
import argparse
import dataclasses
import os
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["demo", "100m"], default="demo")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--aggregator", default="cgc")
    ap.add_argument("--f", type=int, default=1)
    ap.add_argument("--byz", type=int, default=0)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.devices > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp

    from repro import checkpoint as ckpt_lib
    from repro.configs.base import ModelConfig
    from repro.data import make_batch_iterator
    from repro.launch.train import TrainSettings, make_train_step
    from repro.models import model as M
    from repro.models.nn import count_params, split_params
    from repro.optim import adamw, linear_warmup_cosine

    if args.preset == "demo":
        cfg = ModelConfig(name="lm-demo-20m", family="dense", num_layers=6,
                          d_model=320, num_heads=8, num_kv_heads=4,
                          d_ff=1280, vocab_size=8192, vocab_round=64,
                          qk_norm=True, tie_embeddings=True,
                          dtype="float32")
    else:
        cfg = ModelConfig(name="lm-100m", family="dense", num_layers=12,
                          d_model=512, num_heads=8, num_kv_heads=8,
                          d_ff=2048, vocab_size=32000, vocab_round=64,
                          qk_norm=True, dtype="float32")

    mesh = None
    if args.devices > 1:
        mesh = jax.make_mesh((args.devices,), ("data",))

    opt = adamw(linear_warmup_cosine(args.lr, 20, args.steps),
                weight_decay=0.01)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    values, _ = split_params(params)
    print(f"model {cfg.name}: {count_params(values):,d} params; "
          f"devices={args.devices} aggregator={args.aggregator} "
          f"f={args.f} byz={args.byz}")

    state = opt.init(values)
    settings = TrainSettings(aggregator=args.aggregator, f=args.f,
                             n_byz=args.byz, byz_mode="large_norm")
    step_fn, ctx = make_train_step(cfg, opt, settings, mesh, args.batch)
    step_jit = jax.jit(step_fn)
    it = make_batch_iterator(cfg, args.batch, args.seq, seed=0)

    t0 = time.time()
    losses = []
    for s in range(args.steps):
        batch = next(it)
        values, state, metrics = step_jit(values, state, batch,
                                          jnp.asarray(s))
        losses.append(float(metrics["loss"]))
        if s % args.log_every == 0 or s == args.steps - 1:
            dt = time.time() - t0
            tok_s = (s + 1) * args.batch * args.seq / dt
            print(f"step {s:5d}  loss {losses[-1]:.4f}  "
                  f"({tok_s:,.0f} tok/s)", flush=True)
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f}) "
          f"in {time.time() - t0:.1f}s")
    if args.ckpt_dir:
        ckpt_lib.save(args.ckpt_dir, args.steps,
                      {"params": values, "opt": state})
        print("checkpoint written to", args.ckpt_dir)
    assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
