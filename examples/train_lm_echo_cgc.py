"""End-to-end driver: train a language model with CGC-filtered aggregation.

    PYTHONPATH=src python examples/train_lm_echo_cgc.py \
        --preset demo --steps 300            # ~20M params, CPU-friendly
    PYTHONPATH=src python examples/train_lm_echo_cgc.py \
        --preset 100m --steps 200            # ~100M params (slow on CPU)

The trainer is the production path from repro.launch.engine: a Trainer
driver over the replicated strategy — data-parallel workers (simulated
in-process on CPU; mesh shards on real hardware), CGC aggregation over
per-worker gradients, AdamW, complete (values, opt_state, step)
checkpoints, deterministic synthetic data. ``--byz K`` makes K workers
Byzantine to demonstrate the filter on a real model. With a single host
device the "workers" collapse to one — pass --devices 8 to fork 8 CPU
devices for true multi-worker DP.
"""
import argparse
import contextlib
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["demo", "100m"], default="demo")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--aggregator", default="cgc")
    ap.add_argument("--f", type=int, default=1)
    ap.add_argument("--byz", type=int, default=0)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--metrics", default=None,
                    help="jsonl per-round metrics sink")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.devices > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax

    from repro.configs.base import ModelConfig
    from repro.data import make_batch_iterator
    from repro.launch.engine import Trainer, TrainerConfig, TrainSettings
    from repro.models import model as M
    from repro.models.nn import count_params, split_params
    from repro.optim import adamw, linear_warmup_cosine

    if args.preset == "demo":
        cfg = ModelConfig(name="lm-demo-20m", family="dense", num_layers=6,
                          d_model=320, num_heads=8, num_kv_heads=4,
                          d_ff=1280, vocab_size=8192, vocab_round=64,
                          qk_norm=True, tie_embeddings=True,
                          dtype="float32")
    else:
        cfg = ModelConfig(name="lm-100m", family="dense", num_layers=12,
                          d_model=512, num_heads=8, num_kv_heads=8,
                          d_ff=2048, vocab_size=32000, vocab_round=64,
                          qk_norm=True, dtype="float32")

    mesh = None
    if args.devices > 1:
        mesh = jax.make_mesh((args.devices,), ("data",))

    opt = adamw(linear_warmup_cosine(args.lr, 20, args.steps),
                weight_decay=0.01)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    values, _ = split_params(params)
    print(f"model {cfg.name}: {count_params(values):,d} params; "
          f"devices={args.devices} aggregator={args.aggregator} "
          f"f={args.f} byz={args.byz}")

    settings = TrainSettings(aggregator=args.aggregator, f=args.f,
                             n_byz=args.byz, byz_mode="large_norm")
    trainer = Trainer("replicated", cfg, opt, settings, mesh, args.batch,
                      TrainerConfig(log_every=args.log_every,
                                    ckpt_dir=args.ckpt_dir,
                                    metrics_path=args.metrics))
    state = trainer.init_state(values)
    it = make_batch_iterator(cfg, args.batch, args.seq, seed=0)

    t0 = time.time()
    mesh_ctx = jax.set_mesh(mesh) if mesh is not None \
        else contextlib.nullcontext()
    with mesh_ctx:
        state, summary = trainer.fit(state, it, args.steps)
    trainer.close()
    dt = time.time() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"final loss {summary['final_loss']:.4f} "
          f"(from {summary['first_loss']:.4f}) in {dt:.1f}s "
          f"({tok_s:,.0f} tok/s)")
    if args.ckpt_dir:
        print("checkpoint written to", args.ckpt_dir)
    assert summary["final_loss"] < summary["first_loss"], \
        "loss did not improve"


if __name__ == "__main__":
    main()
