"""Serving example: batched greedy decode with a KV cache.

    PYTHONPATH=src python examples/serve_decode.py --arch qwen3-0.6b \
        --batch 4 --prompt-len 32 --gen 64

Builds the reduced variant of any assigned architecture, "prefills" by
running the decode step over the prompt tokens (cache warm-up), then
generates with the jitted serve_step — the same code path the decode_32k /
long_500k dry-runs lower at production shape.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, reduced
from repro.launch.serve import greedy_decode, make_serve_step
from repro.models import model as M
from repro.models.nn import split_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step "
                         f"(DESIGN.md §4)")
    B = args.batch
    max_len = args.prompt_len + args.gen

    values, _ = split_params(M.init_params(cfg, jax.random.PRNGKey(0)))
    cache, _ = split_params(M.init_cache(cfg, B, max_len))
    serve_step, _ = make_serve_step(cfg, None, B)
    step_jit = jax.jit(serve_step)

    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (B, args.prompt_len), 0, cfg.vocab_size,
                                jnp.int32)
    # prefill by stepping the cache over the prompt
    t0 = time.time()
    for t in range(args.prompt_len):
        logits, cache = step_jit(values, cache, prompt[:, t:t + 1],
                                 jnp.full((B,), t, jnp.int32))
    jax.block_until_ready(logits)
    t_pref = time.time() - t0

    first = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    decode = jax.jit(lambda v, c, tok, pos: greedy_decode(
        cfg, v, c, tok, pos, args.gen, serve_step))
    t0 = time.time()
    toks, cache = decode(values, cache, first,
                         jnp.full((B,), args.prompt_len, jnp.int32))
    jax.block_until_ready(toks)
    t_gen = time.time() - t0

    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_pref:.2f}s   generate: {t_gen:.2f}s "
          f"({B * args.gen / t_gen:.1f} tok/s)")
    print("sample token ids:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
