"""Serving example: continuous batching over the paged KV cache.

    PYTHONPATH=src python examples/serve_decode.py --arch qwen3-0.6b \
        --requests 8 --max-batch 4 --prompt-len 32 --gen 64

Builds the reduced variant of an architecture, submits a batch of
synthetic requests with mixed prompt/generation lengths to
``repro.serve.ServeEngine`` — FCFS admission with token-budget packing,
prefill/decode interleaving, preempt-longest on block-pool OOM — and
streams the per-request results: the same continuous-batching code path
the decode_32k / long_500k dry-runs lower at production shape.
"""
import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import model as M
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step "
                         f"(DESIGN.md §4)")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    blocks_needed = -(-(args.prompt_len + args.gen) // args.page_size)
    engine = ServeEngine(cfg, params, ServeConfig(
        max_batch=args.max_batch, page_size=args.page_size,
        num_pages=args.num_pages, max_blocks_per_seq=blocks_needed,
        token_budget=4 * args.prompt_len, log_every=10))

    rng = np.random.default_rng(args.seed)
    handles = []
    for _ in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        gen = int(rng.integers(max(args.gen // 4, 1), args.gen + 1))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).tolist()
        handles.append(engine.submit(prompt, max_new=gen))

    engine.drain()
    summary = engine.summary()
    engine.close()

    print(f"arch={cfg.name} requests={args.requests} "
          f"lanes={args.max_batch} pages={args.num_pages}x{args.page_size}")
    print(f"generated {summary['tokens_generated']} tokens in "
          f"{summary['wall_s']}s ({summary['tokens_per_s']} tok/s); "
          f"latency p50={summary['latency_p50_s']}s "
          f"p99={summary['latency_p99_s']}s")
    h = handles[0]
    print(f"request 0: prompt={len(h.prompt)} generated={len(h.tokens)} "
          f"sample token ids: {h.tokens[:16]}")


if __name__ == "__main__":
    main()
