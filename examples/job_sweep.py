"""Sweep the paper's scenario axes through the declarative job API.

    PYTHONPATH=src python examples/job_sweep.py

Loads the paper quadratic job (experiments/jobs/paper_echo_cgc.json),
then runs the SAME experiment under several registered aggregators by
editing the typed config tree — no entry-point flags, no string
dispatch. Each run leaves its exact config.json + metrics.jsonl in its
own directory under experiments/runs/, so the sweep is reproducible
from the artifacts alone.
"""
import dataclasses

from repro import run


def main():
    base = run.RunConfig.load("experiments/jobs/paper_echo_cgc.json")
    base = run.apply_overrides(base, ["train.steps=20"])

    print(f"{'aggregator':14s} {'first':>10s} {'final':>10s} "
          f"{'bits saved':>10s}")
    for agg in ("cgc", "mean", "median", "trimmed_mean"):
        scen = dataclasses.replace(base.scenario, aggregator=agg)
        # echo-DP's fallback step is CGC-specific; other aggregators run
        # through the plain replicated strategy.
        train = base.train if agg == "cgc" else dataclasses.replace(
            base.train, strategy="replicated")
        cfg = dataclasses.replace(base, name=f"sweep-{agg}",
                                  scenario=scen, train=train)
        result = run.train(cfg)
        s = result.summary
        saved = s.get("bits_saving", 0.0)
        print(f"{agg:14s} {s['first_loss']:10.4f} {s['final_loss']:10.4f} "
              f"{100.0 * saved:9.1f}%")


if __name__ == "__main__":
    main()
