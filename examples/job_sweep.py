"""Sweep the paper's scenario axes through the declarative job API.

    PYTHONPATH=src python examples/job_sweep.py

Loads the paper quadratic job (experiments/jobs/paper_echo_cgc.json) and
expands an aggregator grid over it with ``run.sweep`` — the same
dotted-path machinery the CLI's ``--set`` uses, one job file emitted per
point, so the whole sweep reruns standalone from the artifacts alone:

    python -m repro train --config experiments/runs/sweep-jobs/<point>.json

Each run additionally leaves its exact config.json + metrics.jsonl in
its own directory under experiments/runs/.
"""
from repro import run


def main():
    base = run.RunConfig.load("experiments/jobs/paper_echo_cgc.json")
    base = run.apply_overrides(base, ["train.steps=20"])

    # echo-DP's fallback step is CGC-specific; the other aggregators run
    # through the plain replicated strategy — one extra grid axis.
    points = run.sweep(base, {"scenario.aggregator": ["cgc"]},
                       out_dir="experiments/runs/sweep-jobs")
    points += run.sweep(
        base, {"scenario.aggregator": ["mean", "median", "trimmed_mean"],
               "train.strategy": ["replicated"]},
        out_dir="experiments/runs/sweep-jobs")

    print(f"{'aggregator':14s} {'first':>10s} {'final':>10s} "
          f"{'bits saved':>10s}")
    for cfg in points:
        result = run.train(cfg)
        s = result.summary
        saved = s.get("bits_saving", 0.0)
        print(f"{cfg.scenario.aggregator:14s} {s['first_loss']:10.4f} "
              f"{s['final_loss']:10.4f} {100.0 * saved:9.1f}%")


if __name__ == "__main__":
    main()
