"""Byzantine-tolerant logistic regression with Echo-CGC vs baselines.

    PYTHONPATH=src python examples/train_byzantine_lr.py [--rounds 80]

Trains L2-regularised logistic regression (strongly convex, mu = l2) in the
parameter-server radio network under several attacks, comparing Echo-CGC
against Krum / coordinate-median / trimmed-mean / undefended mean, and
reporting measured communication per aggregator. This mirrors the paper's
setting with a real (synthetic) dataset instead of an abstract quadratic.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import byzantine, costfns, theory
from repro.core.protocol import run_training
from repro.core.types import ProtocolConfig, raw_bits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=80)
    ap.add_argument("--n", type=int, default=20)
    ap.add_argument("--f", type=int, default=2)
    ap.add_argument("--d", type=int, default=50)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    cost = costfns.logistic_l2(key, n_data=2000, d=args.d, batch=64,
                               l2=0.25)
    sigma = min(cost.sigma, 0.9 / jnp.sqrt(args.n).item())
    r, eta, *_ , rho = theory.pick_r_eta(args.n, args.f, cost.L, cost.mu,
                                         sigma)
    eta = min(eta, 0.5 / cost.L)
    cfg = ProtocolConfig(n=args.n, f=args.f, r=r, eta=eta)
    byz = jnp.zeros(args.n, bool).at[:args.f].set(True)
    print(f"logistic regression d={args.d}: L={cost.L:.3f} mu={cost.mu:.3f}"
          f" sigma~{cost.sigma:.3f} -> r={r:.4f} eta={eta:.5f}")

    header = f"{'attack':14s} {'aggregator':13s} {'final Q-Q*':>12s} " \
             f"{'dist^2':>10s} {'Mbits':>8s}"
    print("\n" + header + "\n" + "-" * len(header))
    q_star = float(cost.value(cost.w_star))
    for attack in ["none", "sign_flip", "large_norm", "mean_shift"]:
        for agg, radio in [("cgc", True), ("krum", False),
                           ("median", False), ("trimmed_mean", False),
                           ("mean", False)]:
            tr = run_training(cfg, cost, byzantine.ATTACKS[attack], byz,
                              key, jnp.zeros(args.d), rounds=args.rounds,
                              aggregator=agg, use_radio=radio)
            gap = float(cost.value(tr["w_final"])) - q_star
            mb = float(jnp.sum(tr["bits"])) / 1e6 if radio else \
                args.rounds * args.n * raw_bits(args.d) / 1e6
            name = ("echo-" + agg) if radio else agg
            print(f"{attack:14s} {name:13s} {gap:12.3e} "
                  f"{float(tr['dist2'][-1]):10.2e} {mb:8.2f}")


if __name__ == "__main__":
    main()
